"""Quiver serving launcher — the paper's end-to-end path on the
executor-graph stack.

    PYTHONPATH=src python -m repro.launch.serve --nodes 20000 --requests 400 \
        --policy latency_preferred

Builds the full stack: synthetic skewed graph → PSGS/FAP metrics → feature
placement → tiered store → per-executor latency calibration → N-way
cost-model router → futures-based serving engine; then reports
throughput/latency. With ``--sharded`` (requires ≥2 devices, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU) a third,
distributed executor joins the registry: mesh-local sampling + one-sided
sharded feature reads. With repeatable ``--models name=preset`` flags the
engine co-serves several GNNs over the ONE shared store — each model gets
its own calibration and router (per-model PSGS cut-points), requests are
tagged round-robin, and the report breaks down per model. ``--spill-path``
backs the DISK tier with a real ``np.memmap`` spill file and ``--prefetch``
stages predicted cold rows into a device-side buffer so HOST/DISK reads
leave the request critical path (see ``benchmarks/prefetch.py``).
``--gpu-cache`` adds the request-granularity device cache in front of the
cold tiers (``--gpu-cache-rows`` capacity; controller-sized under
``--adaptive`` — see ``benchmarks/flash_crowd.py``). ``--gateway`` puts the
SLO-aware admission gateway in front of the engine: requests carry a
priority class (``--priority interactive|batch|mixed``) and optional
relative deadline (``--deadline-ms``), the queue is ordered by deadline
slack with anti-starvation aging, hopeless requests are shed before they
ever occupy an executor, and ``--telemetry`` prints the streaming
queue-depth/saturation/per-class-latency samples at the end (see
``benchmarks/gateway_soak.py``).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.core import (Prefetcher, ShardedFeatureStore, TieredFeatureStore,
                        TopologySpec, WorkloadGenerator, compute_fap,
                        compute_psgs, quiver_placement)
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered
from repro.serving import (AdaptiveConfig, AdaptiveController,
                           CostModelRouter, DeviceExecutor, FrequencySketch,
                           GatewayConfig, HostExecutor, MicroBatcher,
                           ModelRegistry, ServingEngine, ServingGateway,
                           ShardedExecutor, StaticScheduler,
                           build_model_entry, calibrate_executors)

# --models presets: hidden layer widths of the GraphSAGE variant each model
# serves (all share the graph, feature store and samplers — only the model
# compute differs, which is exactly what per-model calibration captures)
MODEL_PRESETS = {
    "sage-small": (64, 64),
    "sage-base": (128, 128),
    "sage-wide": (256, 256),
    "sage-deep": (128, 128, 128),
}


def make_infer_fn(d_feat: int, hidden: tuple[int, ...],
                  fanouts: tuple[int, ...], seed: int = 0):
    """Jitted GraphSAGE ``infer_fn(hop_feats, hop_ids[, deep_agg])`` with
    the given hidden widths — one per served model. ``deep_agg`` carries
    the innermost hop pre-reduced by the fused gather→aggregate store path
    (``hop_feats`` then omits that hop; masks still cover it via
    ``hop_ids``)."""
    params = sage_init(jax.random.key(seed), [d_feat, *hidden])

    @jax.jit
    def infer_fn(hop_feats, hop_ids, deep_agg=None):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fanouts, hop_masks=masks,
                            deep_agg=deep_agg)

    return infer_fn


def build_stack(*, nodes: int, avg_degree: float, d_feat: int,
                fanouts: tuple[int, ...], hot_frac: float, seed: int = 0,
                distribution: str = "degree",
                spill_path: str | None = None):
    graph = power_law_graph(nodes, avg_degree, seed=seed)
    rng = np.random.default_rng(seed + 1)
    feats = rng.normal(size=(nodes, d_feat)).astype(np.float32)

    psgs = compute_psgs(graph, fanouts)
    gen = WorkloadGenerator(nodes, graph.out_degree,
                            distribution=distribution, seed=seed + 2)
    fap = compute_fap(graph, fanouts, seed_prob=gen.p)
    topo = TopologySpec(num_pods=1, devices_per_pod=1,
                        rows_per_device=max(nodes // 4, 64),
                        rows_host=max(nodes // 2, 64),
                        hot_replicate_fraction=hot_frac)
    plan = quiver_placement(fap, topo)
    store = TieredFeatureStore.build(feats, plan, spill_path=spill_path)

    infer_fn = make_infer_fn(d_feat, (128, 128), fanouts, seed)

    return graph, feats, psgs, fap, store, gen, infer_fn


def parse_model_specs(specs: list[str]) -> dict[str, tuple[int, ...]]:
    """``name=preset`` flags → {model name: hidden widths}; raises
    SystemExit on malformed specs, duplicate names or unknown presets."""
    models: dict[str, tuple[int, ...]] = {}
    for spec in specs:
        name, sep, preset = spec.partition("=")
        if not sep or not name:
            raise SystemExit(f"--models expects name=preset, got {spec!r}")
        if name in models:
            raise SystemExit(f"--models: duplicate model name {name!r}")
        if preset not in MODEL_PRESETS:
            raise SystemExit(f"--models: unknown preset {preset!r}; "
                             f"choose from {sorted(MODEL_PRESETS)}")
        models[name] = MODEL_PRESETS[preset]
    return models


def build_sharded_store(graph, feats, fap, *, hot_frac: float = 0.25,
                        spill_dir: str | None = None):
    """Mesh + sharded feature store shared by every model's sharded
    executor (built once — the whole point of co-serving is one copy of
    the feature rows). Exits when the runtime has <2 devices. With
    ``spill_dir`` the DISK-tier rows are split into per-shard
    ``DiskSpillTier`` files (shard = id % world) so each shard's cold
    misses read its own mmap, never a cross-shard one."""
    world = len(jax.devices())
    if world < 2:
        raise SystemExit(
            "--sharded needs ≥2 devices; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    mesh = make_mesh((world,), ("x",))
    # rebuild a placement whose warm tier is sharded over the real mesh;
    # size HBM (hot+warm) to cover every node so the sharded store —
    # which serves only the HBM tiers — is exact for any batch
    topo = TopologySpec(num_pods=1, devices_per_pod=world,
                        rows_per_device=max(-(-graph.num_nodes // world),
                                            64),
                        rows_host=max(graph.num_nodes // 2, 64),
                        hot_replicate_fraction=hot_frac)
    splan = quiver_placement(fap, topo)
    sstore = ShardedFeatureStore.from_tiered(
        TieredFeatureStore.build(feats, splan), mesh, "x",
        spill_dir=spill_dir)
    return mesh, sstore, splan


def build_executors(graph, store, fanouts, infer_fn, psgs, *,
                    num_workers: int, max_batch: int, sharded: bool,
                    feats=None, fap=None, hot_frac: float = 0.25,
                    fused: bool = True, fuse_aggregate: bool = False,
                    sharded_spill_dir: str | None = None):
    """Executor registry: host + device, plus the distributed (sharded)
    executor when requested and the runtime has ≥2 devices. ``fused``
    selects the single-dispatch feature-collection path
    (``store.lookup_hops``); ``False`` keeps the legacy per-hop lookups.
    ``fuse_aggregate`` additionally folds the innermost-hop aggregation
    into the gather (``store.lookup_aggregate``); the sharded executor
    downgrades it with a one-time warning (its store serves whole rows
    only — see the support matrix in ``docs/architecture.md``)."""
    executors = {
        "host": HostExecutor(graph, store, fanouts, infer_fn,
                             capacity=num_workers, psgs_table=psgs,
                             fused=fused, fuse_aggregate=fuse_aggregate),
        "device": DeviceExecutor(graph.device_arrays(), store, fanouts,
                                 infer_fn, max_batch=max_batch,
                                 capacity=num_workers, psgs_table=psgs,
                                 fused=fused, fuse_aggregate=fuse_aggregate),
    }
    if sharded:
        mesh, sstore, splan = build_sharded_store(
            graph, feats, fap, hot_frac=hot_frac,
            spill_dir=sharded_spill_dir)
        executors["sharded"] = ShardedExecutor(
            mesh, "x", graph.device_arrays(), sstore, fanouts, infer_fn,
            max_batch=max_batch, psgs_table=psgs, tier_table=splan.tier,
            fused=fused, fuse_aggregate=fuse_aggregate)
    return executors


def make_prefetcher(args, store, fap, controller, hooks, *, sstore=None):
    """``--prefetch`` wiring shared by the single- and multi-model paths:
    build the cold-tier prefetcher, hand it to the adaptive controller
    (refresh per control step, shared sketch) or — without ``--adaptive`` —
    register it as an engine hook with its own sketch and refresh cadence,
    then stage the offline-FAP prediction before serving starts. With a
    sharded store (``sstore``) a second prefetcher drives its per-shard
    staging buffers from the same score signal, so the mesh path sheds
    host callbacks exactly like the single-host one."""
    if not args.prefetch:
        return None
    pf = Prefetcher(store, budget=args.prefetch_budget,
                    refresh_every=(None if controller is not None
                                   else args.adapt_interval))
    if controller is not None:
        controller.attach_prefetcher(pf)
    else:
        pf.sketch = FrequencySketch(store.plan.tier.shape[0])
        hooks.append(pf)
    staged = pf.refresh(scores=fap)
    print(f"[serve] prefetch: staged {staged} cold rows "
          f"(budget {args.prefetch_budget})")
    if sstore is not None:
        spf = Prefetcher(sstore, budget=args.prefetch_budget,
                         refresh_every=(None if controller is not None
                                        else args.adapt_interval))
        if controller is not None:
            controller.attach_prefetcher(spf)
        else:
            spf.sketch = pf.sketch
            hooks.append(spf)
        sstaged = spf.refresh(scores=fap)
        print(f"[serve] prefetch: staged {sstaged} cold rows across the "
              f"mesh shards (budget {args.prefetch_budget})")
    return pf


def make_gpu_cache(args, store, controller):
    """``--gpu-cache`` wiring shared by the single- and multi-model paths:
    put a request-granularity device cache in front of the store's cold
    tiers (``--gpu-cache-rows`` capacity). With ``--adaptive`` it shares
    the controller's frequency sketch — eviction is frequency-weighted and
    the control step resizes the capacity from the measured cold working
    set; without it the capacity stays fixed and eviction is plain CLOCK."""
    if not args.gpu_cache:
        return None
    from repro.core import GPUFeatureCache
    cache = GPUFeatureCache.for_store(
        store, args.gpu_cache_rows,
        sketch=controller.sketch if controller is not None else None)
    store.attach_cache(cache)
    print(f"[serve] gpu-cache: {args.gpu_cache_rows} rows in front of the "
          f"cold tiers"
          + (" (controller-sized)" if controller is not None else ""))
    return cache


def make_gateway(args, engine, controller):
    """``--gateway`` wiring shared by the single- and multi-model paths:
    put the SLO-aware admission gateway in front of the engine and — with
    ``--adaptive`` — hand it to the controller so each control step tunes
    the admission window (``queue_limit``) from observed saturation and
    deadline sheds."""
    if not args.gateway:
        return None
    gw = ServingGateway(engine,
                        config=GatewayConfig(queue_limit=args.gateway_queue))
    if controller is not None:
        controller.attach_gateway(gw)
    print(f"[serve] gateway: queue_limit={args.gateway_queue}, "
          f"priority mix {args.priority!r}"
          + (f", deadline {args.deadline_ms:.0f} ms"
             if args.deadline_ms is not None else ""))
    return gw


def priority_stream_kwargs(args) -> dict:
    """``--priority`` / ``--deadline-ms`` → ``WorkloadGenerator.stream``
    kwargs: class tags (cycled round-robin for ``mixed``) and the relative
    deadline carried by interactive requests (batch requests stay
    deadline-free so aging — not slack — is what keeps them moving)."""
    if not args.gateway:
        return {}
    dl = args.deadline_ms * 1e-3 if args.deadline_ms is not None else None
    if args.priority == "mixed":
        return {"priorities": ("interactive", "batch"),
                "deadlines": (dl, None)}
    return {"priorities": (args.priority,), "deadlines": (dl,)}


def _serve_and_report(args, engine, psgs, reqs, controller,
                      prefetcher=None, cache=None, gateway=None) -> None:
    """Shared tail of the single- and multi-model launcher paths: warmup,
    then the gateway path (per-request SLO admission), the optional
    micro-batched stream (with ``--adapt-micro`` attachment) or pre-formed
    batches, then the JSON report."""
    engine.warmup([reqs[0]])
    if gateway is not None:
        metrics = gateway.serve(reqs)
        print("[serve] gateway:", json.dumps(gateway.report()))
        if args.telemetry:
            samples = gateway.telemetry_samples()
            print(f"[serve] telemetry: {len(samples)} samples, last 5:")
            for s in samples[-5:]:
                print("  ", json.dumps(s))
    elif args.micro_batch > 0:
        # stream path: per-request ingest, then the PSGS-aware coalescing
        # stage feeds the fused gather super-batches under its deadline
        from repro.core import DynamicBatcher
        micro = MicroBatcher(deadline_s=args.micro_deadline_ms * 1e-3,
                             max_seeds=args.micro_batch, psgs_table=psgs)
        if args.adapt_micro and controller is not None:
            # auto-tuning nudges the stage of the first model on the stream
            # (serve_stream clones one per further model)
            controller.attach_micro(micro)
        metrics = engine.serve_stream(
            reqs, DynamicBatcher(deadline_s=0.0, max_batch=1), micro=micro)
        print(f"[serve] micro-batching: {micro.emitted} super-batches, "
              f"{micro.coalesced} coalesced, final bounds "
              f"max_seeds={micro.max_seeds} "
              f"deadline_ms={micro.deadline_s * 1e3:.2f}")
    else:
        metrics = engine.run([[r] for r in reqs])
    print(json.dumps(metrics.summary(), indent=2))
    if controller is not None:
        print("[serve] adaptation:", json.dumps(controller.report()))
    if prefetcher is not None:
        print("[serve] prefetch:", json.dumps(prefetcher.report()))
    if cache is not None:
        print("[serve] gpu-cache:", json.dumps(cache.report()))


def serve_multi_model(args, fanouts, graph, psgs, fap, store, gen) -> None:
    """The ``--models`` path: one engine, one shared store, N models.

    Per model: its own ``infer_fn`` (preset hidden widths), executor set
    over the shared store, calibration, and router — so each model gets its
    own PSGS cut-point. Requests are tagged round-robin across the models;
    admission stays global; the report breaks down per model.
    """
    specs = parse_model_specs(args.models)
    order = np.argsort(psgs)
    cal_batches = [order[int(q * graph.num_nodes):][:args.batch]
                   .astype(np.int64) for q in np.linspace(0.05, 0.95, 8)]
    registry = ModelRegistry()
    for i, (name, hidden) in enumerate(specs.items()):
        infer = make_infer_fn(args.d_feat, hidden, fanouts, seed=i)
        entry = build_model_entry(
            name, graph=graph, store=store, fanouts=fanouts, infer_fn=infer,
            psgs_table=psgs, policy=args.policy, capacity=args.workers,
            max_batch=args.batch, fused=args.fused, rng_seed=i,
            calibration_batches=cal_batches)
        registry.add(entry)
        cut = entry.router.crossover("host", "device")
        print(f"[serve] model {name!r} ({'x'.join(map(str, hidden))}): "
              f"host/device PSGS cut-point {cut:.1f}")

    hooks = []
    controller = None
    if args.adaptive:
        controller = AdaptiveController(
            graph, fanouts, store, registry.routers(), psgs_table=psgs,
            config=AdaptiveConfig(interval_batches=args.adapt_interval,
                                  rows_per_step=args.adapt_rows,
                                  drift_threshold=args.drift_threshold))
        hooks.append(controller)
    prefetcher = make_prefetcher(args, store, fap, controller, hooks)
    cache = make_gpu_cache(args, store, controller)
    engine = ServingEngine(registry, max_inflight=args.max_inflight,
                           admission=args.admission, hooks=hooks)
    gateway = make_gateway(args, engine, controller)
    reqs = list(gen.stream(args.requests, seeds_per_request=args.batch,
                           models=list(specs),
                           **priority_stream_kwargs(args)))
    _serve_and_report(args, engine, psgs, reqs, controller, prefetcher,
                      cache, gateway)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--nodes", type=int, default=20000)
    p.add_argument("--avg-degree", type=float, default=12.0)
    p.add_argument("--d-feat", type=int, default=128)
    p.add_argument("--fanouts", default="10,5")
    p.add_argument("--requests", type=int, default=300)
    p.add_argument("--batch", type=int, default=32)
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--policy", default="latency_preferred",
                   choices=["cpu_preferred", "gpu_preferred",
                            "latency_preferred", "throughput_preferred",
                            "host_only", "device_only"])
    p.add_argument("--hot-frac", type=float, default=0.25)
    p.add_argument("--sharded", action="store_true",
                   help="register the distributed executor (needs ≥2 devices)")
    p.add_argument("--max-inflight", type=int, default=64,
                   help="admission window: outstanding batches")
    p.add_argument("--admission", default="wait", choices=["wait", "shed"],
                   help="behavior when the admission window is full")
    p.add_argument("--models", action="append", default=None,
                   metavar="NAME=PRESET",
                   help="co-serve a named model from a preset (repeatable; "
                        f"presets: {sorted(MODEL_PRESETS)}). All models "
                        "share the graph + feature store; each gets its own "
                        "calibration, router and metrics. Omit for the "
                        "single-model path.")
    p.add_argument("--adaptive", action="store_true",
                   help="enable the online workload-adaptation loop: live "
                        "FAP re-placement + router drift refit")
    p.add_argument("--adapt-micro", action="store_true",
                   help="let the adaptive controller auto-tune the micro-"
                        "batcher deadline/max_seeds toward the measured "
                        "latency-curve knee (needs --adaptive and "
                        "--micro-batch > 0)")
    p.add_argument("--adapt-interval", type=int, default=32,
                   help="control period in completed batches")
    p.add_argument("--adapt-rows", type=int, default=64,
                   help="max feature rows migrated per control step")
    p.add_argument("--drift-threshold", type=float, default=0.25,
                   help="relative latency-curve drift that triggers a "
                        "router refit")
    p.add_argument("--fused", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="fused feature collection (cross-hop dedup + one "
                        "tiered_gather dispatch); --no-fused keeps the "
                        "legacy per-hop store lookups")
    p.add_argument("--fuse-aggregate", action=argparse.BooleanOptionalAction,
                   default=False,
                   help="fold the innermost-hop aggregation into the "
                        "gather dispatch (gather_aggregate kernel; the "
                        "dense neighbor tensor is never materialized)")
    p.add_argument("--micro-batch", type=int, default=0,
                   help="coalesce requests into gather-friendly "
                        "super-batches of up to this many seeds before "
                        "admission (0 = off)")
    p.add_argument("--micro-deadline-ms", type=float, default=4.0,
                   help="max milliseconds a request may wait in the "
                        "micro-batching stage")
    p.add_argument("--prefetch", action="store_true",
                   help="stage predicted cold-tier (HOST/DISK) rows into a "
                        "device-side buffer off the critical path; lookups "
                        "resolve staged ids from device memory and only "
                        "fall back to the synchronous host callback on a "
                        "prefetch miss. Refreshed per control step with "
                        "--adaptive, else every --adapt-interval batches.")
    p.add_argument("--prefetch-budget", type=int, default=1024,
                   help="max cold rows staged per prefetch refresh "
                        "(device staging-buffer size)")
    p.add_argument("--gpu-cache", action="store_true",
                   help="request-granularity device cache in front of the "
                        "cold tiers: cold rows are fetched from host/disk "
                        "at most once per residency, repeats are HBM "
                        "gathers. With --adaptive the controller sizes the "
                        "capacity from the measured cold working set.")
    p.add_argument("--gpu-cache-rows", type=int, default=2048,
                   help="device-cache row capacity (initial capacity under "
                        "--adaptive)")
    p.add_argument("--gateway", action="store_true",
                   help="SLO-aware admission gateway in front of the "
                        "engine: priority classes, deadline-slack queue "
                        "ordering with anti-starvation aging, and "
                        "shed-before-dispatch for hopeless requests")
    p.add_argument("--gateway-queue", type=int, default=256,
                   help="gateway admission-queue depth bound (tuned live "
                        "under --adaptive)")
    p.add_argument("--priority", default="batch",
                   choices=["interactive", "batch", "mixed"],
                   help="priority class tagged on the request stream "
                        "(mixed = alternating interactive/batch; needs "
                        "--gateway)")
    p.add_argument("--deadline-ms", type=float, default=None,
                   help="relative deadline carried by interactive requests "
                        "(mixed keeps batch requests deadline-free; needs "
                        "--gateway)")
    p.add_argument("--telemetry", action="store_true",
                   help="print the gateway's streaming telemetry "
                        "(queue depth, saturation, per-class latency "
                        "percentiles) after serving (needs --gateway)")
    p.add_argument("--spill-path", default=None,
                   help="write DISK-tier rows to an np.memmap spill file at "
                        "this path (the real cold store); omit to keep them "
                        "in host memory")
    p.add_argument("--sharded-spill-dir", default=None,
                   help="directory for the sharded store's per-shard "
                        "DiskSpillTier files (shard = id %% world); omit to "
                        "serve sharded cold misses from the tiered source "
                        "store (needs --sharded)")
    args = p.parse_args()
    fanouts = tuple(int(x) for x in args.fanouts.split(","))
    if args.adapt_micro and not (args.adaptive and args.micro_batch > 0):
        raise SystemExit("--adapt-micro needs --adaptive and "
                         "--micro-batch > 0")
    if not args.gateway and (args.priority != "batch" or args.telemetry
                             or args.deadline_ms is not None):
        raise SystemExit("--priority/--deadline-ms/--telemetry need "
                         "--gateway")
    if args.gateway and args.micro_batch > 0:
        raise SystemExit("--gateway dispatches per request (admission "
                         "ordering is the point); drop --micro-batch")
    if args.sharded_spill_dir is not None and not args.sharded:
        raise SystemExit("--sharded-spill-dir needs --sharded")

    graph, feats, psgs, fap, store, gen, infer_fn = build_stack(
        nodes=args.nodes, avg_degree=args.avg_degree, d_feat=args.d_feat,
        fanouts=fanouts, hot_frac=args.hot_frac, spill_path=args.spill_path)
    print(f"[serve] graph: {graph.num_nodes} nodes / {graph.num_edges} edges;"
          f" tiers: {store.plan.tier_counts()}"
          + (f"; spill: {args.spill_path}" if args.spill_path else ""))

    static_policy = args.policy in ("host_only", "device_only")
    if args.models:
        if static_policy:
            raise SystemExit("--models needs a cost-model policy "
                             "(per-model routing is the point)")
        serve_multi_model(args, fanouts, graph, psgs, fap, store, gen)
        return
    if args.sharded and static_policy:
        print("[serve] note: static policy can never route to the sharded "
              "executor; skipping its construction")
    executors = build_executors(graph, store, fanouts, infer_fn, psgs,
                                num_workers=args.workers,
                                max_batch=args.batch,
                                sharded=args.sharded and not static_policy,
                                feats=feats, fap=fap,
                                hot_frac=args.hot_frac, fused=args.fused,
                                fuse_aggregate=args.fuse_aggregate,
                                sharded_spill_dir=args.sharded_spill_dir)
    print(f"[serve] executors: {sorted(executors)}")

    if static_policy:
        router = StaticScheduler("host" if args.policy == "host_only"
                                 else "device")
    else:
        # calibration (paper Fig. 6), generalized to every registered
        # executor: measure across the PSGS range, fit avg+tail curves
        batches = []
        order = np.argsort(psgs)
        for q in np.linspace(0.05, 0.95, 8):
            seeds = order[int(q * graph.num_nodes):][:args.batch]
            batches.append(seeds.astype(np.int64))
        curves = calibrate_executors(executors, batches, psgs, repeats=2)
        router = CostModelRouter.from_curves(psgs, curves, args.policy,
                                             executors=executors)
        mid = float(np.median(psgs)) * args.batch
        ests = {n: router.estimate(n, mid) * 1e3 for n in router.names}
        print(f"[serve] calibrated est @median-batch (ms): "
              f"{ {k: round(v, 2) for k, v in ests.items()} }")

    hooks = []
    controller = None
    if args.adaptive:
        controller = AdaptiveController(
            graph, fanouts, store,
            router if not static_policy else None, psgs_table=psgs,
            config=AdaptiveConfig(interval_batches=args.adapt_interval,
                                  rows_per_step=args.adapt_rows,
                                  drift_threshold=args.drift_threshold))
        hooks.append(controller)
    prefetcher = make_prefetcher(
        args, store, fap, controller, hooks,
        sstore=getattr(executors.get("sharded"), "sstore", None))
    cache = make_gpu_cache(args, store, controller)
    engine = ServingEngine(executors, router,
                           max_inflight=args.max_inflight,
                           admission=args.admission, hooks=hooks)
    gateway = make_gateway(args, engine, controller)
    reqs = list(gen.stream(args.requests, seeds_per_request=args.batch,
                           **priority_stream_kwargs(args)))
    _serve_and_report(args, engine, psgs, reqs, controller, prefetcher,
                      cache, gateway)


if __name__ == "__main__":
    main()

"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gin-tu --steps 100 \
        [--ckpt-dir /tmp/ckpt] [--nodes 8192] [--resume]

Runs real training of the selected GNN arch on a synthetic power-law graph
sized to the host (full configs are exercised via the dry-run; this launcher
is the single-host/few-chip path with the same code: sampler → feature store
→ model → AdamW → checkpoint manager). For the ~100M-param end-to-end run
see examples/train_gnn_100m.py.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.gnn_common import make_concrete_batch
from repro.training import AdamW, CheckpointManager, run_training


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="gin-tu")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--nodes", type=int, default=4096)
    p.add_argument("--edges", type=int, default=32768)
    p.add_argument("--d-feat", type=int, default=64)
    p.add_argument("--classes", type=int, default=16)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--lr", type=float, default=1e-3)
    args = p.parse_args()

    import repro.configs.gnn_common as G
    arch = get_arch(args.arch)
    assert arch.family == "gnn", "train launcher drives GNN archs; " \
        "LM/recsys training is exercised via dry-run + examples"
    info = dict(nodes=args.nodes, edges=args.edges, d_feat=args.d_feat,
                classes=args.classes, graphs=None)

    # reuse the arch's loss through the adapter captured in build_cell
    from repro.configs import gin_tu, meshgraphnet, schnet, equiformer_v2
    adapters = {"gin-tu": gin_tu, "schnet": schnet,
                "meshgraphnet": meshgraphnet, "equiformer-v2": equiformer_v2}
    mod = adapters[args.arch]
    init = getattr(mod, "_reduced_init", None) or mod._init
    params = init(jax.random.key(0), args.d_feat, args.classes, "custom")
    print(f"[train] {args.arch}: "
          f"{sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params)):,}"
          " params")

    def batch_fn(step: int) -> dict:
        return make_concrete_batch(info, seed=step)

    def loss_fn(p, batch):
        return mod._loss(p, batch, info, "custom")

    ckpt = (CheckpointManager(args.ckpt_dir, async_write=True)
            if args.ckpt_dir else None)
    state = run_training(loss_fn=loss_fn, params=params,
                         opt=AdamW(lr=args.lr, weight_decay=0.0),
                         batch_fn=batch_fn, steps=args.steps, ckpt=ckpt,
                         ckpt_every=args.ckpt_every)
    print(f"[train] done at step {state.step}")


if __name__ == "__main__":
    main()

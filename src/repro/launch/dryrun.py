"""Multi-pod dry-run: lower + compile EVERY (arch × shape) cell on the
production meshes and dump memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out artifacts/dryrun.json]

The two XLA_FLAGS lines below MUST stay the first statements (before any
other import, jax locks the device count on first init); nothing else sets
this flag globally, so tests/benches keep seeing 1 device.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import get_arch, list_archs
from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.launch.mesh import make_production_mesh, mesh_world


def loop_factor(arch_name: str, shape: str) -> int:
    """XLA cost_analysis counts while-loop bodies ONCE; models that scan
    over layers therefore under-report per-step totals by the trip count.
    This returns the outermost scan trip count so reports can show both the
    amortized (raw) and first-order-corrected totals. Nested loops
    (blockwise-attention KV chunks, edge chunks) compound further — the
    §Roofline napkin math in EXPERIMENTS.md covers the hillclimbed cells
    exactly; everywhere else treat corrected values as lower bounds."""
    lm_layers = {"qwen1.5-4b": 40, "qwen3-4b": 36, "codeqwen1.5-7b": 32,
                 "deepseek-moe-16b": 28, "phi3.5-moe-42b": 32}
    if arch_name in lm_layers:
        micro = 1
        if shape == "train_4k":                  # grad-accumulation scan
            micro = 8 if arch_name == "phi3.5-moe-42b" else 4
        return lm_layers[arch_name] * micro
    gnn_layers = {"equiformer-v2": 12, "schnet": 3, "meshgraphnet": 15}
    if arch_name in gnn_layers:
        return gnn_layers[arch_name]
    if arch_name == "din" and shape == "retrieval_cand":
        return 32  # candidate-chunk scan
    return 1  # gin (unrolled), din forward paths


def run_cell(arch_name: str, shape: str, multi_pod: bool,
             *, verbose: bool = True) -> dict:
    arch = get_arch(arch_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    world = mesh_world(mesh)
    rec = {"arch": arch_name, "shape": shape,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "world": world, "ok": False}
    t0 = time.time()
    try:
        cell = arch.build_cell(shape, mesh)
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        with mesh:
            lowered = jitted.lower(*cell.args)
            rec["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t1

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_hbm_bytes": int(mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        flops = float(ca.get("flops", 0.0))
        bytes_accessed = float(ca.get("bytes accessed", 0.0))
        rec["cost"] = {"flops": flops, "bytes_accessed": bytes_accessed,
                       "transcendentals": float(ca.get("transcendentals",
                                                       0.0))}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo, world=world)
        rec["collectives"] = {"counts": coll.counts,
                              "bytes_by_kind": coll.bytes_by_kind,
                              "total_bytes": coll.total_bytes}
        rec["roofline"] = roofline_terms(flops=flops,
                                         bytes_accessed=bytes_accessed,
                                         collective_bytes=coll.total_bytes)
        lf = loop_factor(arch_name, shape)
        rec["loop_factor"] = lf
        rec["roofline_corrected"] = roofline_terms(
            flops=flops * lf, bytes_accessed=bytes_accessed * lf,
            collective_bytes=coll.total_bytes * lf)
        rec["kind"] = cell.kind
        rec["ok"] = True
        if verbose:
            r = rec["roofline"]
            print(f"[ok] {arch_name:17s} {shape:14s} mesh={rec['mesh']:8s} "
                  f"compile={rec['compile_s']:6.1f}s "
                  f"hbm={rec['memory']['peak_hbm_bytes']/2**30:7.2f}GiB "
                  f"compute={r['compute_s']*1e3:9.3f}ms "
                  f"mem={r['memory_s']*1e3:9.3f}ms "
                  f"coll={r['collective_s']*1e3:9.3f}ms "
                  f"dom={r['dominant']}", flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch_name} {shape} multi_pod={multi_pod}: "
                  f"{rec['error']}", flush=True)
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="single arch id (default all)")
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="both",
                   choices=["single", "multi", "both"])
    p.add_argument("--out", default="artifacts/dryrun.json")
    p.add_argument("--append", action="store_true")
    args = p.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    records = []
    if args.append and os.path.exists(args.out):
        records = json.load(open(args.out))

    for name in archs:
        arch = get_arch(name)
        shapes = [args.shape] if args.shape else list(arch.shape_names)
        for shape in shapes:
            for multi in meshes:
                records = [r for r in records
                           if not (r["arch"] == name and r["shape"] == shape
                                   and r["world"] == (512 if multi else 256))]
                records.append(run_cell(name, shape, multi))
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)

    ok = sum(r["ok"] for r in records)
    print(f"\n{ok}/{len(records)} cells compiled; results → {args.out}")


if __name__ == "__main__":
    main()

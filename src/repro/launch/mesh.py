"""Production mesh builders (see MULTI-POD DRY-RUN spec).

Functions, not module-level constants: importing this module never touches
jax device state. ``make_production_mesh(multi_pod=True)`` needs 512 devices —
the dry-run entrypoint sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(*, model: int | None = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = model or 1
    data = n // model
    return make_mesh((data, model), ("data", "model"))


def mesh_world(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))

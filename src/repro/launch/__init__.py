"""Launch tooling: production meshes, the multi-pod dry-run, and the
train/serve CLI drivers."""

"""Extract roofline terms from a compiled SPMD module.

``compiled.cost_analysis()`` provides per-device HLO FLOPs and bytes.
Collective bytes are NOT in cost_analysis — we parse the optimized HLO text
and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, weighting by the standard ring-transfer
factors over the parsed replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over (possibly tuple) shape string like
    '(f32[16,128]{1,0}, u32[])' or 'bf16[2,16]{1,0}'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict           # ring-weighted per-device bytes on the wire

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str, *, world: int) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVES}
    bytes_by_kind = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},: ]+?)\s+"
                     r"(all-gather-start|all-gather|all-reduce-start|"
                     r"all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute-start|collective-permute)\(", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        size = _shape_bytes(shape_str)
        g = _group_size(line, world)
        if g <= 1:
            continue
        ring = (g - 1) / g
        if op == "all-gather":
            wire = size * ring                # output is the gathered shape
        elif op == "all-reduce":
            wire = 2.0 * size * ring          # reduce-scatter + all-gather
        elif op == "reduce-scatter":
            wire = size * g * ring            # output is the scattered shard
        elif op == "all-to-all":
            wire = size * ring
        else:  # collective-permute
            wire = size
        counts[op] += 1
        bytes_by_kind[op] += wire
    return CollectiveStats(counts=counts, bytes_by_kind=bytes_by_kind)


def roofline_terms(*, flops: float, bytes_accessed: float,
                   collective_bytes: float) -> dict:
    """All inputs are per-device quantities of one step."""
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {**terms, "dominant": dominant,
            "roofline_fraction": (bound / total) if total > 0 else 0.0,
            "step_lower_bound_s": bound}

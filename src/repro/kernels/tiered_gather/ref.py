"""Pure-jnp oracle for the tiered two-source gather."""
from __future__ import annotations

import jax.numpy as jnp


def tiered_gather_ref(tier: jnp.ndarray, slot: jnp.ndarray, hot: jnp.ndarray,
                      warm: jnp.ndarray) -> jnp.ndarray:
    safe = jnp.maximum(slot, 0)
    hot_rows = jnp.take(hot, jnp.minimum(safe, hot.shape[0] - 1), axis=0)
    warm_rows = jnp.take(warm, jnp.minimum(safe, warm.shape[0] - 1), axis=0)
    out = jnp.where((tier == 0)[:, None], hot_rows,
                    jnp.where((tier == 1)[:, None], warm_rows, 0.0))
    return out.astype(hot.dtype)

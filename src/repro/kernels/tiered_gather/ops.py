"""Jitted entry: Pallas on TPU, oracle elsewhere."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.tiered_gather.kernel import tiered_gather_pallas
from repro.kernels.tiered_gather.ref import tiered_gather_ref


@partial(jax.jit, static_argnames=("block_rows", "use_pallas"))
def tiered_gather(tier: jnp.ndarray, slot: jnp.ndarray, hot: jnp.ndarray,
                  warm: jnp.ndarray, *, block_rows: int = 8,
                  use_pallas: bool | None = None) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return tiered_gather_pallas(tier, slot, hot, warm,
                                    block_rows=block_rows,
                                    interpret=jax.default_backend() != "tpu")
    return tiered_gather_ref(tier, slot, hot, warm)

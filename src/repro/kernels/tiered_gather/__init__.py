from repro.kernels.tiered_gather.kernel import tiered_gather_pallas
from repro.kernels.tiered_gather.ops import tiered_gather
from repro.kernels.tiered_gather.ref import tiered_gather_ref

__all__ = ["tiered_gather", "tiered_gather_pallas", "tiered_gather_ref"]

"""Pallas TPU kernel for the one-sided-read engine's fused two-level gather.

The tiered feature store resolves each requested id to (tier, slot) via the
lookup tables (paper §5.3's "feature lookup table"). The device-resident part
of a lookup is then a *two-source* gather: hot rows come from the replicated
cache, warm rows from the local shard. Fusing the source select into one
kernel avoids materializing two full gathers + a select (3× the HBM traffic).

ids are pre-resolved to (tier, slot) by ops.py (two cheap (M,) gathers);
the kernel streams rows from whichever table owns each slot. Address-sorted
ids (the paper's TLB optimization) make consecutive DMAs near-sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _tiered_kernel(tier_ref, slot_ref, hot_ref, warm_ref, o_ref, *,
                   rows: int):
    def body(i, _):
        t = tier_ref[i, 0]
        s = slot_ref[i, 0]
        hot_row = hot_ref[pl.ds(jnp.where(t == 0, s, 0), 1), :]
        warm_row = warm_ref[pl.ds(jnp.where(t == 1, s, 0), 1), :]
        row = jnp.where(t == 0, hot_row.astype(jnp.float32),
                        jnp.where(t == 1, warm_row.astype(jnp.float32), 0.0))
        o_ref[pl.ds(i, 1), :] = row.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, rows, body, 0)


def tiered_gather_pallas(tier: jnp.ndarray, slot: jnp.ndarray,
                         hot: jnp.ndarray, warm: jnp.ndarray, *,
                         block_rows: int = 8,
                         interpret: bool = True) -> jnp.ndarray:
    """tier/slot: (M,) int32 (tier 0=hot, 1=warm, ≥2 → zeros);
    hot: (H, d); warm: (W, d). Returns (M, d)."""
    m = tier.shape[0]
    d = hot.shape[1]
    nb = -(-m // block_rows)
    pad = nb * block_rows - m
    tier_p = jnp.pad(tier, (0, pad), constant_values=99)[:, None]
    slot_p = jnp.pad(slot, (0, pad))[:, None]

    kernel = functools.partial(_tiered_kernel, rows=block_rows)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, d), hot.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(tier_p, slot_p, hot, warm)
    return out[:m]

from repro.kernels.gather_aggregate.autotune import autotune_gather_aggregate
from repro.kernels.gather_aggregate.kernel import gather_aggregate_pallas
from repro.kernels.gather_aggregate.ops import gather_aggregate
from repro.kernels.gather_aggregate.ref import gather_aggregate_ref

__all__ = ["gather_aggregate", "gather_aggregate_pallas",
           "gather_aggregate_ref", "autotune_gather_aggregate"]

"""Pure-jnp oracle for the fused gather→aggregate kernel.

Deliberately phrased as ``(rows * mask).sum(axis=1)`` — the exact expression
``models.gnn_basic.sage_layered`` uses for its masked neighbor aggregation —
so the CPU serve path (which dispatches this oracle) is bit-identical to the
unfused gather-then-aggregate model path, not merely allclose.
"""
from __future__ import annotations

import jax.numpy as jnp


def gather_aggregate_ref(tier: jnp.ndarray, slot: jnp.ndarray,
                         hot: jnp.ndarray, warm: jnp.ndarray,
                         cold: jnp.ndarray) -> jnp.ndarray:
    """tier/slot: (S, fan) int32; hot/warm/cold: row tables sharing dim d.
    Returns (S, d) per-segment sums; tier ∉ {0, 1, 2} contributes zero."""
    safe = jnp.maximum(slot, 0)
    hot_r = jnp.take(hot, jnp.minimum(safe, hot.shape[0] - 1), axis=0)
    warm_r = jnp.take(warm, jnp.minimum(safe, warm.shape[0] - 1), axis=0)
    cold_r = jnp.take(cold, jnp.minimum(safe, cold.shape[0] - 1), axis=0)
    rows = jnp.where(
        (tier == 0)[..., None], hot_r,
        jnp.where((tier == 1)[..., None], warm_r,
                  jnp.where((tier == 2)[..., None], cold_r, 0.0)))
    m = (tier <= 2).astype(rows.dtype)[..., None]
    return (rows * m).sum(axis=1).astype(hot.dtype)

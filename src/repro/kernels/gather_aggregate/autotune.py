"""Block-size autotune harness for the fused gather→aggregate kernel.

Sweeps ``block_rows`` (segment-block height → VMEM scratch rows, grid
length) and ``block_dim`` (feature-dim tile width → scratch columns, second
grid axis) over the caller's real shapes and picks the fastest config.

On this CPU-only container the kernel runs in interpret mode, so the
timings rank *dataflow* cost (loop trip counts, block bookkeeping), not HBM
bandwidth. Real-hardware hook: on a TPU host ``ops.gather_aggregate``
dispatches the compiled Pallas kernel automatically (``use_pallas=None`` →
backend check), so this same harness — unchanged — times real DMA+VPU
executions; pass the production (S, fan, d) shapes and persist the returned
``best`` config next to the serving config.
"""
from __future__ import annotations

import time

import jax

from .ops import gather_aggregate

DEFAULT_BLOCK_ROWS = (4, 8, 16, 32)
DEFAULT_BLOCK_DIMS = (0,)  # 0 → no feature-dim tiling (single dim block)


def _divisor_dims(d: int) -> tuple[int, ...]:
    cands = [c for c in (32, 64, 128, 256) if c < d and d % c == 0]
    return (0, *cands)


def autotune_gather_aggregate(tier, slot, hot, warm, cold, *,
                              block_rows_candidates=DEFAULT_BLOCK_ROWS,
                              block_dim_candidates=None,
                              repeats: int = 3) -> dict:
    """Time every (block_rows, block_dim) candidate on the given inputs.

    Returns ``{"best": {"block_rows": .., "block_dim": ..},
    "timings_us": {"RxD": median_us, ...}, "interpret": bool}``. Numbers are
    medians of ``repeats`` runs after one warmup (compile excluded).
    """
    if block_dim_candidates is None:
        block_dim_candidates = _divisor_dims(int(hot.shape[1]))
    interpret = jax.default_backend() != "tpu"
    timings: dict[str, float] = {}
    best = None
    best_us = None
    for br in block_rows_candidates:
        for bd in block_dim_candidates:
            gather_aggregate(tier, slot, hot, warm, cold, block_rows=br,
                             block_dim=bd,
                             use_pallas=True).block_until_ready()
            samples = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                gather_aggregate(tier, slot, hot, warm, cold,
                                 block_rows=br, block_dim=bd,
                                 use_pallas=True).block_until_ready()
                samples.append((time.perf_counter() - t0) * 1e6)
            samples.sort()
            med = samples[len(samples) // 2]
            timings[f"{br}x{bd}"] = med
            if best_us is None or med < best_us:
                best_us = med
                best = {"block_rows": br, "block_dim": bd}
    return {"best": best, "timings_us": timings, "interpret": interpret}

"""Pallas TPU kernel fusing tier-aware row gather with segment aggregation.

The serve path's largest tensor is the sampled-neighbor feature matrix:
``tiered_gather`` writes a dense (n_sampled, d) gather result to HBM and the
model's first aggregation layer immediately reads it back to reduce each
fan-sized segment — two full trips through memory for data that is consumed
exactly once. This kernel folds the segment reduction into the gather: per
(tier, slot)-addressed child it pulls the row straight from whichever tier
buffer owns it (HOT replica, WARM shard, or the compact pre-resolved cold
buffer) and accumulates into the per-seed output segment in a VMEM scratch.
The dense neighbor tensor is never materialized.

Addressing: ``tier``/``slot`` are (S, fan) int32 with one row per output
segment. Tier codes 0=hot, 1=warm, 2=cold-buffer; anything else (ops.py pads
with 99, invalid children carry 99) contributes nothing — a degree-0 segment
therefore yields an exact zero row, matching ``segment_spmm`` semantics.
Accumulation is sequential fp32 over the fan axis, the same order as
``tiered_gather``+``segment_spmm``, so the fused result is bit-identical to
that two-kernel composition.

Grid: (segment_blocks, dim_blocks). The second axis tiles the feature
dimension in ``block_dim`` columns so the autotune harness can trade VMEM
scratch footprint against grid overhead; per-column accumulation order is
unchanged, so tiling never perturbs the numerics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _gather_agg_kernel(tier_ref, slot_ref, hot_ref, warm_ref, cold_ref,
                       o_ref, acc_ref, *, fan: int, block_dim: int):
    r = o_ref.shape[0]
    jd = pl.program_id(1) * block_dim
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def seg_body(i, _):
        def child_body(n, _):
            t = tier_ref[i, n]
            s = slot_ref[i, n]
            hot_row = hot_ref[pl.ds(jnp.where(t == 0, s, 0), 1),
                              pl.ds(jd, block_dim)]
            warm_row = warm_ref[pl.ds(jnp.where(t == 1, s, 0), 1),
                                pl.ds(jd, block_dim)]
            cold_row = cold_ref[pl.ds(jnp.where(t == 2, s, 0), 1),
                                pl.ds(jd, block_dim)]
            row = jnp.where(
                t == 0, hot_row.astype(jnp.float32),
                jnp.where(t == 1, warm_row.astype(jnp.float32),
                          jnp.where(t == 2, cold_row.astype(jnp.float32),
                                    0.0)))
            acc_ref[pl.ds(i, 1), :] += row
            return 0

        jax.lax.fori_loop(0, fan, child_body, 0)
        return 0

    jax.lax.fori_loop(0, r, seg_body, 0)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gather_aggregate_pallas(tier: jnp.ndarray, slot: jnp.ndarray,
                            hot: jnp.ndarray, warm: jnp.ndarray,
                            cold: jnp.ndarray, *,
                            block_rows: int = 8,
                            block_dim: int = 0,
                            interpret: bool = True) -> jnp.ndarray:
    """tier/slot: (S, fan) int32 (tier 0=hot, 1=warm, 2=cold, else → zero
    contribution); hot: (H, d); warm: (W, d); cold: (K, d). Returns (S, d):
    per-segment sums of the addressed rows. ``block_dim`` ≤ 0 or a
    non-divisor of d disables feature-dim tiling (single dim block)."""
    s, fan = tier.shape
    d = hot.shape[1]
    if s == 0 or d == 0:
        return jnp.zeros((s, d), hot.dtype)
    if fan == 0:
        return jnp.zeros((s, d), hot.dtype)
    if block_dim <= 0 or d % block_dim:
        block_dim = d
    nb = -(-s // block_rows)
    ndb = d // block_dim
    pad = nb * block_rows - s
    tier_p = jnp.pad(tier, ((0, pad), (0, 0)), constant_values=99)
    slot_p = jnp.pad(slot, ((0, pad), (0, 0)))

    kernel = functools.partial(_gather_agg_kernel, fan=fan,
                               block_dim=block_dim)
    out = pl.pallas_call(
        kernel,
        grid=(nb, ndb),
        in_specs=[
            pl.BlockSpec((block_rows, fan), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows, fan), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),     # hot replica in HBM
            pl.BlockSpec(memory_space=pl.ANY),     # warm shard in HBM
            pl.BlockSpec(memory_space=pl.ANY),     # resolved cold rows
        ],
        out_specs=pl.BlockSpec((block_rows, block_dim), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, d), hot.dtype),
        scratch_shapes=[pltpu.VMEM((block_rows, block_dim), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tier_p, slot_p, hot, warm, cold)
    return out[:s]

"""Jitted dispatch for the fused gather→aggregate kernel.

``use_pallas=None`` auto-selects: the Pallas kernel on TPU, the pure-jnp
oracle elsewhere. On CPU the oracle *is* the serve path — it evaluates the
same jnp expression as the unfused model aggregation, keeping the fused
collect bit-identical there; the Pallas kernel (interpret mode off-TPU) is
exercised by tests and the autotune harness.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import gather_aggregate_pallas
from .ref import gather_aggregate_ref


@partial(jax.jit, static_argnames=("block_rows", "block_dim", "use_pallas"))
def gather_aggregate(tier: jnp.ndarray, slot: jnp.ndarray,
                     hot: jnp.ndarray, warm: jnp.ndarray,
                     cold: jnp.ndarray, *,
                     block_rows: int = 8,
                     block_dim: int = 0,
                     use_pallas: bool | None = None) -> jnp.ndarray:
    """Fused tier-gather + segment-sum. tier/slot: (S, fan) int32 addresses
    (tier 0=hot, 1=warm, 2=cold, other → zero contribution); hot/warm/cold:
    (·, d) row tables. Returns (S, d) segment sums in fp32 accumulation."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return gather_aggregate_pallas(
            tier, slot, hot, warm, cold, block_rows=block_rows,
            block_dim=block_dim,
            interpret=jax.default_backend() != "tpu")
    return gather_aggregate_ref(tier, slot, hot, warm, cold)

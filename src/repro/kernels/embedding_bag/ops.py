"""Jitted entry: Pallas on TPU, oracle elsewhere."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@partial(jax.jit, static_argnames=("mode", "block_rows", "use_pallas"))
def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  weights: jnp.ndarray | None = None, *, mode: str = "sum",
                  block_rows: int = 8,
                  use_pallas: bool | None = None) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return embedding_bag_pallas(table, ids, weights, mode=mode,
                                    block_rows=block_rows,
                                    interpret=jax.default_backend() != "tpu")
    return embedding_bag_ref(table, ids, weights, mode=mode)

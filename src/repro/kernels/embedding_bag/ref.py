"""Pure-jnp EmbeddingBag oracle (jnp.take + masked reduce)."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray,
                      weights: jnp.ndarray | None = None, *,
                      mode: str = "sum") -> jnp.ndarray:
    valid = (ids >= 0)
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    w = valid.astype(table.dtype)
    if weights is not None:
        w = w * weights
    out = (rows * w[..., None]).sum(axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(valid.sum(-1), 1)[..., None].astype(out.dtype)
    return out

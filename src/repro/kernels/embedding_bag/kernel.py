"""Pallas TPU EmbeddingBag — the recsys/feature-aggregation hot path.

JAX has no native EmbeddingBag; this is the TPU kernel for
``out[b] = reduce_{j∈bag_b} w_bj · table[ids[b, j]]`` with sum/mean modes.
Same ELL-style dataflow as segment_spmm: the id/weight tile lives in VMEM,
the (possibly huge) table stays in HBM and rows stream in via dynamic-slice
DMAs; one destination row per kernel row, fp32 accumulation in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _bag_kernel(ids_ref, w_ref, table_ref, o_ref, acc_ref, cnt_ref, *,
                bag: int, weighted: bool, mean: bool):
    r = o_ref.shape[0]
    acc_ref[...] = jnp.zeros_like(acc_ref)
    cnt_ref[...] = jnp.zeros_like(cnt_ref)

    def row_body(i, _):
        def bag_body(n, _):
            idx = ids_ref[i, n]
            valid = idx >= 0
            row = table_ref[pl.ds(jnp.maximum(idx, 0), 1), :].astype(
                jnp.float32)
            w = jnp.where(valid, 1.0, 0.0)
            if weighted:
                w = w * w_ref[i, n].astype(jnp.float32)
            acc_ref[pl.ds(i, 1), :] += row * w
            cnt_ref[pl.ds(i, 1), :] += jnp.where(valid, 1.0, 0.0)
            return 0

        jax.lax.fori_loop(0, bag, bag_body, 0)
        return 0

    jax.lax.fori_loop(0, r, row_body, 0)
    out = acc_ref[...]
    if mean:
        out = out / jnp.maximum(cnt_ref[...][:, :1], 1.0)
    o_ref[...] = out.astype(o_ref.dtype)


def embedding_bag_pallas(table: jnp.ndarray, ids: jnp.ndarray,
                         weights: jnp.ndarray | None = None, *,
                         mode: str = "sum", block_rows: int = 8,
                         interpret: bool = True) -> jnp.ndarray:
    """table: (V, d); ids: (B, bag) int32 (-1 pad); weights: (B, bag)|None."""
    bsz, bag = ids.shape
    d = table.shape[1]
    if bsz == 0 or bag == 0 or d == 0:
        # empty grid / zero-length dynamic slices are rejected by
        # pallas_call; an empty bag reduces to zeros (mean guard included),
        # like the oracle
        return jnp.zeros((bsz, d), table.dtype)
    nb = -(-bsz // block_rows)
    pad = nb * block_rows - bsz
    ids_p = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    w_p = (jnp.pad(weights, ((0, pad), (0, 0))) if weights is not None
           else jnp.zeros((nb * block_rows, bag), table.dtype))

    kernel = functools.partial(_bag_kernel, bag=bag,
                               weighted=weights is not None,
                               mean=mode == "mean")
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, bag), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, bag), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, d), table.dtype),
        scratch_shapes=[pltpu.VMEM((block_rows, d), jnp.float32),
                        pltpu.VMEM((block_rows, 128), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(ids_p, w_p, table)
    return out[:bsz]

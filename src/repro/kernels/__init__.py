"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel subpackage ships kernel.py (pl.pallas_call + BlockSpec),
ops.py (jitted dispatch wrapper) and ref.py (pure-jnp oracle); tests sweep
shapes/dtypes in interpret mode against the oracle.
"""
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gather_aggregate import gather_aggregate
from repro.kernels.segment_spmm import segment_spmm
from repro.kernels.tiered_gather import tiered_gather

__all__ = ["flash_attention", "segment_spmm", "embedding_bag",
           "tiered_gather", "gather_aggregate"]

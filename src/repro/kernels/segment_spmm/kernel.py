"""Pallas TPU kernel for GNN message aggregation (SpMM) in ELL layout.

The hot loop of every assigned GNN arch is ``out[i] = Σ_{j∈N(i)} w_ij·x[j]``.
On TPU we use the ELL (padded-neighbor) layout: ids (N, Dmax) int32 with -1
padding — fixed shapes, no data-dependent control flow, and each destination
row is owned by exactly one kernel instance (no atomics, which TPUs lack).

Grid: (num_node_blocks,). Per block: the (R, Dmax) id tile rides in VMEM, the
feature table stays in HBM (``pl.ANY``) and rows are pulled with dynamic
slices — on real TPU these become DMA gathers that the sequential grid
pipelines against the accumulation FLOPs; ``interpret=True`` validates the
same dataflow on CPU. Rows accumulate in a (R, d) fp32 VMEM scratch.

This layout choice (vs CSR two-phase sort-reduce) is the TPU adaptation of
the paper's CUDA sparse-matmul primitive used for PSGS/FAP (§4.1): degree
skew costs padding instead of warp divergence, and Quiver's own metrics tell
us the padding waste up front.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _spmm_kernel(ids_ref, w_ref, feat_ref, o_ref, acc_ref, *, dmax: int,
                 weighted: bool):
    r = o_ref.shape[0]
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def row_body(i, _):
        def nbr_body(n, _):
            idx = ids_ref[i, n]
            valid = idx >= 0
            safe = jnp.maximum(idx, 0)
            row = feat_ref[pl.ds(safe, 1), :].astype(jnp.float32)
            w = jnp.where(valid, 1.0, 0.0)
            if weighted:
                w = w * w_ref[i, n].astype(jnp.float32)
            acc_ref[pl.ds(i, 1), :] += row * w
            return 0

        jax.lax.fori_loop(0, dmax, nbr_body, 0)
        return 0

    jax.lax.fori_loop(0, r, row_body, 0)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def segment_spmm_pallas(ids: jnp.ndarray, feat: jnp.ndarray,
                        weights: jnp.ndarray | None = None, *,
                        block_rows: int = 8,
                        interpret: bool = True) -> jnp.ndarray:
    """ids: (N, Dmax) int32 (-1 pad); feat: (M, d); weights: (N, Dmax) or
    None. Returns (N, d): per-row reduced neighbor features."""
    n, dmax = ids.shape
    d = feat.shape[1]
    if n == 0 or dmax == 0 or d == 0:
        # empty grid / zero-length dynamic slices are rejected by
        # pallas_call; an empty reduction is exactly zeros, like the oracle
        return jnp.zeros((n, d), feat.dtype)
    nb = -(-n // block_rows)
    pad = nb * block_rows - n
    ids_p = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    w = weights if weights is not None else jnp.ones((1, 1), feat.dtype)
    w_p = (jnp.pad(w, ((0, pad), (0, 0))) if weights is not None
           else jnp.zeros((nb * block_rows, dmax), feat.dtype))

    kernel = functools.partial(_spmm_kernel, dmax=dmax,
                               weighted=weights is not None)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, dmax), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, dmax), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),     # feature table in HBM
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, d), feat.dtype),
        scratch_shapes=[pltpu.VMEM((block_rows, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(ids_p, w_p, feat)
    return out[:n]

from repro.kernels.segment_spmm.kernel import segment_spmm_pallas
from repro.kernels.segment_spmm.ops import segment_spmm
from repro.kernels.segment_spmm.ref import coo_to_ell, segment_spmm_ref

__all__ = ["segment_spmm", "segment_spmm_pallas", "segment_spmm_ref",
           "coo_to_ell"]

"""Jitted entry: Pallas on TPU, oracle elsewhere (identical semantics)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_spmm.kernel import segment_spmm_pallas
from repro.kernels.segment_spmm.ref import segment_spmm_ref


@partial(jax.jit, static_argnames=("block_rows", "use_pallas"))
def segment_spmm(ids: jnp.ndarray, feat: jnp.ndarray,
                 weights: jnp.ndarray | None = None, *, block_rows: int = 8,
                 use_pallas: bool | None = None) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return segment_spmm_pallas(ids, feat, weights,
                                   block_rows=block_rows,
                                   interpret=jax.default_backend() != "tpu")
    return segment_spmm_ref(ids, feat, weights)

"""Pure-jnp oracle for the ELL segment-SpMM kernel + COO↔ELL converters."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def segment_spmm_ref(ids: jnp.ndarray, feat: jnp.ndarray,
                     weights: jnp.ndarray | None = None) -> jnp.ndarray:
    valid = (ids >= 0)
    rows = jnp.take(feat, jnp.maximum(ids, 0), axis=0)  # (N, Dmax, d)
    w = valid.astype(feat.dtype)
    if weights is not None:
        w = w * weights
    return (rows * w[..., None]).sum(axis=1)


def coo_to_ell(src: np.ndarray, dst: np.ndarray, num_nodes: int,
               *, dmax: int | None = None) -> np.ndarray:
    """Pack a COO edge list into the (N, Dmax) ELL neighbor table
    (out[i] rows hold the in-neighbors of i, i.e. src of edges with dst=i)."""
    deg = np.bincount(dst, minlength=num_nodes)
    if dmax is None:
        dmax = int(deg.max()) if deg.size else 1
    ell = np.full((num_nodes, dmax), -1, dtype=np.int32)
    fill = np.zeros(num_nodes, dtype=np.int64)
    for s, d in zip(src, dst):
        if fill[d] < dmax:
            ell[d, fill[d]] = s
            fill[d] += 1
    return ell

"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True) -> jnp.ndarray:
    """Naive O(S²) attention with GQA expansion; fp32 internals."""
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    group = h // kv
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)

"""Pallas TPU flash-attention kernel (causal / full, GQA-aware).

Grid: (batch·heads, num_q_blocks, num_kv_blocks) with the kv dimension
"arbitrary" (sequential) so the online-softmax state lives in VMEM scratch
across kv steps. Block shapes are (block_q, head_dim) / (block_kv, head_dim)
— head_dim is kept whole (128 for every assigned arch, MXU-aligned), and the
running max/denominator are stored lane-replicated (block_q, 128) as usual on
TPU. Causal blocks strictly above the diagonal are skipped with ``pl.when``
(no FLOPs, no VREG traffic — the DMA is already amortized by the pipeline).

GQA is handled in the BlockSpec index maps: the kv block index maps query
head h → kv head h // (H // KV), so no materialized KV expansion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_kv: int,
                  seq_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # kv block strictly above the diagonal ⇒ fully masked ⇒ skip.
        run = (ik * block_kv) <= (iq * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                      # (bk, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kv_pos = ik * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = kv_pos < seq_kv
        if causal:
            q_pos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            mask = mask & (kv_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                  # (bq, 1)
        m_cur = s.max(axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                         # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.broadcast_to(
            p.sum(axis=1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[:, :1], 1e-20)).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, block_q: int = 128,
                           block_kv: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, Sq, H, dh); k/v: (B, Skv, KV, dh), H % KV == 0. Returns like q.

    ``interpret=True`` runs the kernel body on CPU (validation); on TPU pass
    ``interpret=False``.
    """
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    assert h % kv == 0
    group = h // kv
    scale = 1.0 / np.sqrt(dh)

    block_q = min(block_q, max(sq, 8))
    block_kv = min(block_kv, max(skv, 8))
    nq = -(-sq // block_q)
    nk = -(-skv // block_kv)
    pad_q = nq * block_q - sq
    pad_kv = nk * block_kv - skv

    qt = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, dh)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * kv, skv, dh)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * kv, skv, dh)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kt = jnp.pad(kt, ((0, 0), (0, pad_kv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pad_kv), (0, 0)))

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        return ((bh // h) * kv + (bh % h) // group, ik, 0)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_kv=block_kv,
                               seq_kv=skv)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), q_map),
            pl.BlockSpec((1, block_kv, dh), kv_map),
            pl.BlockSpec((1, block_kv, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * block_q, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max
            pltpu.VMEM((block_q, 128), jnp.float32),  # running denom
            pltpu.VMEM((block_q, dh), jnp.float32),   # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :sq].reshape(b, h, sq, dh)
    return jnp.moveaxis(out, 1, 2)

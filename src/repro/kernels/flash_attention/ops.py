"""Jitted public entry point: Pallas on TPU, interpret-mode kernel or the
blockwise-XLA path elsewhere."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                   "force_interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_kv: int = 128,
                    force_interpret: bool = False) -> jnp.ndarray:
    interpret = force_interpret or not _on_tpu()
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_kv=block_kv, interpret=interpret)

"""An injectable monotonic clock for deadline tests.

Every timing-sensitive component (`ServingEngine`, `MicroBatcher`,
`DynamicBatcher`, `Prefetcher`, `ServingGateway`) takes a ``clock=``
parameter: a zero-arg callable returning seconds, defaulting to
``time.monotonic``. Tests pass a :class:`FakeClock` and call
``advance()`` instead of sleeping, which kills the slow-host flake
class outright — a deadline test runs in microseconds and cannot be
perturbed by scheduler jitter.
"""
from __future__ import annotations

import threading


class FakeClock:
    """A deterministic stand-in for ``time.monotonic``.

    The instance itself is the clock callable (``clock()`` returns the
    current fake time in seconds); time only moves when the test calls
    :meth:`advance`. Thread-safe: serving components read the clock from
    executor pool threads while the test advances it.
    """

    def __init__(self, start: float = 0.0):
        self._lock = threading.Lock()
        self._now = float(start)

    def __call__(self) -> float:
        """Current fake time in seconds (monotonic, never decreases)."""
        with self._lock:
            return self._now

    def advance(self, dt: float) -> float:
        """Move time forward by ``dt`` seconds and return the new time."""
        if dt < 0:
            raise ValueError(f"FakeClock cannot go backwards (dt={dt})")
        with self._lock:
            self._now += float(dt)
            return self._now

    def sleep(self, dt: float) -> None:
        """Drop-in for ``time.sleep`` in monkeypatched code paths."""
        self.advance(dt)

    def __repr__(self) -> str:
        return f"FakeClock(t={self():.6f})"

"""Deterministic test infrastructure (fake clocks — no sleeps in tests).

Anything here is importable from production code paths only as a default
argument *type*, never as a default *value*: runtime components default
to ``time.monotonic`` and accept any zero-arg float callable, so this
package stays test-only at runtime.
"""
from repro.testing.clock import FakeClock

__all__ = ["FakeClock"]

"""Neighbor samplers: padded fixed-shape device sampler (XLA/TPU path) and an
exact dynamic-shape host sampler (the "CPU path").

The contrast between the two is the heart of Quiver's hybrid scheduling on
TPU: the device sampler always pays for the padded worst case
``B·∏ fanout_k`` while the host sampler pays only for the realized neighbor
set. PSGS predicts the realized size, i.e. how much of the device padding is
wasted — exactly the routing signal of paper §4.2.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class SampledHops:
    """Layered (bipartite) sample. ``hops[0]`` are the seeds; ``hops[k]`` has
    shape ``(B·∏_{h<=k} f_h,)`` with -1 padding; ``hops[k]`` entry
    ``i*f_k + j`` is the j-th sampled neighbor of ``hops[k-1][i]``."""

    hops: list[jnp.ndarray]
    fanouts: tuple[int, ...]

    def all_nodes(self) -> jnp.ndarray:
        return jnp.concatenate([h.reshape(-1) for h in self.hops])

    @property
    def padded_size(self) -> int:
        return sum(int(np.prod(h.shape)) for h in self.hops)


def _sample_one_hop(key: jax.Array, indptr: jnp.ndarray, indices: jnp.ndarray,
                    frontier: jnp.ndarray, fanout: int) -> jnp.ndarray:
    """Uniform neighbor sampling, fixed output shape (|frontier|·fanout,).

    Nodes with deg<=fanout return their full neighbor list (without
    replacement); for deg>fanout sampling is with replacement (standard
    GraphSAGE-style approximation; see DESIGN.md §5.1).
    """
    f = jnp.maximum(frontier, 0)
    start = indptr[f]
    deg = indptr[f + 1] - start
    valid = frontier >= 0
    deg = jnp.where(valid, deg, 0)
    r = jax.random.randint(key, (frontier.shape[0], fanout), 0,
                           jnp.maximum(deg, 1)[:, None])
    take_all = deg[:, None] <= fanout
    offs = jnp.where(take_all, jnp.arange(fanout, dtype=jnp.int32)[None, :], r)
    in_range = offs < deg[:, None]
    offs = jnp.minimum(offs, jnp.maximum(deg[:, None] - 1, 0))
    nbr = indices[start[:, None] + offs]
    nbr = jnp.where(valid[:, None] & in_range, nbr, -1)
    return nbr.reshape(-1)


@partial(jax.jit, static_argnames=("fanouts",))
def device_sample(key: jax.Array, indptr: jnp.ndarray, indices: jnp.ndarray,
                  seeds: jnp.ndarray, fanouts: tuple[int, ...]) -> list[jnp.ndarray]:
    hops = [seeds]
    frontier = seeds
    for k, fan in enumerate(fanouts):
        key, sub = jax.random.split(key)
        frontier = _sample_one_hop(sub, indptr, indices, frontier, fan)
        hops.append(frontier)
    return hops


def sample_khop(key: jax.Array, graph_dev: tuple[jnp.ndarray, jnp.ndarray],
                seeds: jnp.ndarray, fanouts: Sequence[int]) -> SampledHops:
    indptr, indices = graph_dev
    hops = device_sample(key, indptr, indices, seeds, tuple(fanouts))
    return SampledHops(hops=hops, fanouts=tuple(fanouts))


# --------------------------------------------------------------------------
# Host (exact) sampler — dynamic shapes, numpy. The "CPU path".
# --------------------------------------------------------------------------
def host_sample(rng: np.random.Generator, graph: CSRGraph, seeds: np.ndarray,
                fanouts: Sequence[int]) -> list[np.ndarray]:
    """Exact k-hop sampling; hop arrays have realized (dynamic) sizes."""
    hops = [np.asarray(seeds, dtype=np.int64)]
    frontier = hops[0]
    indptr, indices = graph.indptr, graph.indices
    for fan in fanouts:
        outs = []
        for v in frontier:
            if v < 0:
                continue
            s, e = indptr[v], indptr[v + 1]
            deg = e - s
            if deg == 0:
                continue
            if deg <= fan:
                outs.append(indices[s:e])
            else:
                outs.append(indices[s + rng.integers(0, deg, size=fan)])
        frontier = (np.concatenate(outs) if outs
                    else np.empty((0,), dtype=indices.dtype))
        hops.append(frontier.astype(np.int64))
    return hops


def realized_size(hops: list[np.ndarray]) -> int:
    return int(sum(h.size for h in hops))


def host_sample_dense(rng: np.random.Generator, graph: CSRGraph,
                      seeds: np.ndarray,
                      fanouts: Sequence[int]) -> list[np.ndarray]:
    """Exact host sampling in the *dense fan-out layout* (hop k has shape
    (len(seeds)·∏f, ) with -1 padding) — same layout the device sampler
    emits, so one model path serves both executors. Exactness: every node
    with deg ≤ fan contributes all its neighbors exactly once (no
    replacement duplicates), which is what makes the host path cheaper on
    low-PSGS requests (fewer realized feature fetches)."""
    hops = [np.asarray(seeds, dtype=np.int32)]
    indptr, indices = graph.indptr, graph.indices
    frontier = hops[0]
    for fan in fanouts:
        out = np.full((frontier.shape[0], fan), -1, dtype=np.int32)
        for i, v in enumerate(frontier):
            if v < 0:
                continue
            s, e = indptr[v], indptr[v + 1]
            deg = e - s
            if deg == 0:
                continue
            if deg <= fan:
                out[i, :deg] = indices[s:e]
            else:
                out[i] = indices[s + rng.integers(0, deg, size=fan)]
        frontier = out.reshape(-1)
        hops.append(frontier)
    return hops


# --------------------------------------------------------------------------
# Fixed-size dedup (the TLB-analogue id-sort optimization, DESIGN.md §2)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("capacity",))
def fixed_size_unique(ids: jnp.ndarray, capacity: int
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sorted unique ids padded to ``capacity`` with -1, plus an inverse map
    so gathered rows can be scattered back to the original (duplicated) order.

    ids: (M,) int32 with -1 padding. Returns (uniq (capacity,), inv (M,)).
    Ids beyond capacity (after dedup) are dropped — callers size capacity to
    the padded worst case so this never truncates valid ids.
    """
    m = ids.shape[0]
    order = jnp.argsort(ids)
    s = ids[order]
    first = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    first = first & (s >= 0)
    pos = jnp.cumsum(first) - 1  # dense rank among uniques, valid where first
    rank_per_elem = pos  # rank of the unique bucket each sorted elem falls in
    uniq = jnp.full((capacity,), -1, dtype=ids.dtype)
    uniq = uniq.at[jnp.where(first, pos, capacity)].set(s, mode="drop")
    inv_sorted = jnp.where(s >= 0, rank_per_elem, capacity - 1)
    inv = jnp.zeros((m,), dtype=jnp.int32).at[order].set(
        inv_sorted.astype(jnp.int32))
    return uniq, inv

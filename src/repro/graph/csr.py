"""CSR graph container used across the system.

All device-side code works on two int32 arrays (indptr, indices) plus optional
edge weights. Host-side metadata (numpy mirrors) is kept for the exact host
sampler and for metric precomputation on very large graphs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Compressed-sparse-row adjacency. Out-edges of node i are
    ``indices[indptr[i]:indptr[i+1]]``."""

    indptr: np.ndarray  # (N+1,) int64/int32
    indices: np.ndarray  # (E,) int32
    num_nodes: int
    edge_weight: Optional[np.ndarray] = None  # (E,) float32, defaults uniform

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    # ---- constructors -------------------------------------------------
    @staticmethod
    def from_edge_index(src: np.ndarray, dst: np.ndarray, num_nodes: int,
                        edge_weight: Optional[np.ndarray] = None) -> "CSRGraph":
        """Build CSR from a COO edge list (src -> dst)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        order = np.argsort(src, kind="stable")
        src_s, dst_s = src[order], dst[order]
        counts = np.bincount(src_s, minlength=num_nodes)
        indptr = np.zeros(num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        ew = None
        if edge_weight is not None:
            ew = np.asarray(edge_weight, dtype=np.float32)[order]
        return CSRGraph(indptr=indptr, indices=dst_s.astype(np.int32),
                        num_nodes=int(num_nodes), edge_weight=ew)

    # ---- conversions ---------------------------------------------------
    def to_coo(self) -> tuple[np.ndarray, np.ndarray]:
        src = np.repeat(np.arange(self.num_nodes, dtype=np.int32),
                        self.out_degree)
        return src, self.indices

    def reverse(self) -> "CSRGraph":
        """CSC view as a CSR over in-edges (for FAP / in-neighbor passes)."""
        src, dst = self.to_coo()
        return CSRGraph.from_edge_index(dst, src, self.num_nodes,
                                        self.edge_weight)

    def device_arrays(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        return (jnp.asarray(self.indptr, dtype=jnp.int32),
                jnp.asarray(self.indices, dtype=jnp.int32))

    def validate(self) -> None:
        assert self.indptr.shape == (self.num_nodes + 1,)
        assert self.indptr[0] == 0 and self.indptr[-1] == self.num_edges
        assert np.all(np.diff(self.indptr) >= 0)
        if self.num_edges:
            assert self.indices.min() >= 0
            assert self.indices.max() < self.num_nodes

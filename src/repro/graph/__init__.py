from repro.graph.csr import CSRGraph
from repro.graph.generators import (grid_mesh_graph, molecule_batch,
                                    power_law_graph, preset_graph,
                                    radius_graph, uniform_graph)
from repro.graph.sampler import (SampledHops, device_sample, fixed_size_unique,
                                 host_sample, host_sample_dense,
                                 realized_size, sample_khop)
from repro.graph.segment import (scatter_spmm, segment_max, segment_mean,
                                 segment_softmax, segment_sum)

__all__ = [
    "CSRGraph", "power_law_graph", "uniform_graph", "grid_mesh_graph",
    "radius_graph", "molecule_batch", "preset_graph", "SampledHops",
    "sample_khop", "device_sample", "host_sample", "host_sample_dense",
    "realized_size",
    "fixed_size_unique", "segment_sum", "segment_mean", "segment_max",
    "segment_softmax", "scatter_spmm",
]

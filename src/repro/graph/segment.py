"""Segment (scatter-reduce) primitives — the message-passing substrate.

JAX has no CSR SpMM; every GNN aggregation in this repo goes through these
wrappers around ``jax.ops.segment_*`` so the Pallas ``segment_spmm`` kernel can
be swapped in for the hot path (see repro.kernels.segment_spmm.ops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(data: jnp.ndarray, segment_ids: jnp.ndarray,
                num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_mean(data: jnp.ndarray, segment_ids: jnp.ndarray,
                 num_segments: int, *, eps: float = 1e-9) -> jnp.ndarray:
    tot = segment_sum(data, segment_ids, num_segments)
    cnt = segment_sum(jnp.ones(data.shape[:1], dtype=data.dtype), segment_ids,
                      num_segments)
    return tot / (cnt[(...,) + (None,) * (tot.ndim - 1)] + eps)


def segment_max(data: jnp.ndarray, segment_ids: jnp.ndarray,
                num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_softmax(scores: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """Per-segment softmax over edge scores (GAT edge-softmax)."""
    seg_max = jax.ops.segment_max(scores, segment_ids,
                                  num_segments=num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(scores - seg_max[segment_ids])
    denom = segment_sum(ex, segment_ids, num_segments)
    return ex / (denom[segment_ids] + 1e-9)


def scatter_spmm(src_feat: jnp.ndarray, src_idx: jnp.ndarray,
                 dst_idx: jnp.ndarray, num_dst: int,
                 edge_weight: jnp.ndarray | None = None) -> jnp.ndarray:
    """out[d] = Σ_{e: dst[e]=d} w[e] · src_feat[src[e]] — the SpMM primitive.

    Invalid edges are marked with negative indices and contribute zero.
    """
    msg = src_feat[jnp.maximum(src_idx, 0)]
    valid = ((src_idx >= 0) & (dst_idx >= 0)).astype(msg.dtype)
    if edge_weight is not None:
        valid = valid * edge_weight.astype(msg.dtype)
    msg = msg * valid[:, None]
    return segment_sum(msg, jnp.maximum(dst_idx, 0), num_dst)

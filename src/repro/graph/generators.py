"""Synthetic graph generators.

Real deployments load OGB/Reddit/MAG from disk; this container is offline, so
every dataset used by tests/benchmarks is synthesized with the same statistical
shape (power-law degree skew is what makes Quiver's metrics non-trivial).
Full-scale configs (ogbn-products, reddit-like) are only ever *lowered* through
ShapeDtypeStructs in the dry-run; generators are called at reduced scale.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.csr import CSRGraph


def power_law_graph(num_nodes: int, avg_degree: float, *, exponent: float = 1.6,
                    seed: int = 0, max_degree: Optional[int] = None) -> CSRGraph:
    """Directed graph with zipf-skewed *in*-popularity (preferential
    attachment-like): a few hub nodes receive a large share of edges — the skew
    regime Quiver targets (paper §2.2)."""
    rng = np.random.default_rng(seed)
    num_edges = int(num_nodes * avg_degree)
    # Out-degrees: heavy-tailed (zipf) — out-degree skew is what makes
    # neighbor-sampling cost irregular (paper Fig. 2), since sampling
    # follows out-edges.
    base = rng.zipf(2.0, size=num_nodes).astype(np.float64)
    cap = max_degree if max_degree is not None else max(num_nodes // 4, 8)
    base = np.minimum(base, cap)
    out_deg = np.maximum(
        np.round(base * (avg_degree / max(base.mean(), 1e-9))), 1
    ).astype(np.int64)
    out_deg = np.minimum(out_deg, cap)
    deficit = num_edges - int(out_deg.sum())
    if deficit > 0:
        bump = rng.integers(0, num_nodes, size=deficit)
        np.add.at(out_deg, bump, 1)
    src = np.repeat(np.arange(num_nodes), out_deg)
    # In-endpoints: zipf-ranked popularity over a random node permutation.
    ranks = rng.permutation(num_nodes)
    weights = 1.0 / np.power(np.arange(1, num_nodes + 1, dtype=np.float64),
                             exponent)
    weights /= weights.sum()
    dst_rank = rng.choice(num_nodes, size=src.shape[0], p=weights)
    dst = ranks[dst_rank]
    keep = src != dst  # drop self loops
    return CSRGraph.from_edge_index(src[keep], dst[keep], num_nodes)


def uniform_graph(num_nodes: int, avg_degree: float, *, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    num_edges = int(num_nodes * avg_degree)
    src = rng.integers(0, num_nodes, size=num_edges)
    dst = rng.integers(0, num_nodes, size=num_edges)
    keep = src != dst
    return CSRGraph.from_edge_index(src[keep], dst[keep], num_nodes)


def grid_mesh_graph(nx: int, ny: int) -> CSRGraph:
    """Bidirectional 2-D grid mesh (MeshGraphNet-style simulation mesh)."""
    ids = np.arange(nx * ny).reshape(nx, ny)
    src, dst = [], []
    for (a, b) in ((ids[:-1, :], ids[1:, :]), (ids[:, :-1], ids[:, 1:])):
        src += [a.ravel(), b.ravel()]
        dst += [b.ravel(), a.ravel()]
    src = np.concatenate(src)
    dst = np.concatenate(dst)
    return CSRGraph.from_edge_index(src, dst, nx * ny)


def radius_graph(positions: np.ndarray, cutoff: float,
                 max_neighbors: Optional[int] = None) -> CSRGraph:
    """Molecular radius graph over 3-D coordinates (SchNet / Equiformer)."""
    n = positions.shape[0]
    d2 = np.sum((positions[:, None, :] - positions[None, :, :]) ** 2, axis=-1)
    mask = (d2 < cutoff ** 2) & ~np.eye(n, dtype=bool)
    src, dst = np.nonzero(mask)
    if max_neighbors is not None and src.size:
        order = np.lexsort((d2[src, dst], src))
        src, dst = src[order], dst[order]
        rank = np.zeros_like(src)
        _, start = np.unique(src, return_index=True)
        for s in start:
            e = s
            while e < src.size and src[e] == src[s]:
                rank[e] = e - s
                e += 1
        keep = rank < max_neighbors
        src, dst = src[keep], dst[keep]
    return CSRGraph.from_edge_index(src, dst, n)


def molecule_batch(batch: int, atoms_per_mol: int, *, seed: int = 0,
                   cutoff: float = 2.0) -> tuple[CSRGraph, np.ndarray, np.ndarray]:
    """Block-diagonal batch of random molecules.

    Returns (graph over batch*atoms nodes, positions (N,3), mol_id (N,))."""
    rng = np.random.default_rng(seed)
    all_src, all_dst, all_pos = [], [], []
    for m in range(batch):
        pos = rng.normal(scale=1.2, size=(atoms_per_mol, 3)).astype(np.float32)
        g = radius_graph(pos, cutoff)
        s, d = g.to_coo()
        all_src.append(s + m * atoms_per_mol)
        all_dst.append(d + m * atoms_per_mol)
        all_pos.append(pos)
    n = batch * atoms_per_mol
    graph = CSRGraph.from_edge_index(np.concatenate(all_src),
                                     np.concatenate(all_dst), n)
    mol_id = np.repeat(np.arange(batch, dtype=np.int32), atoms_per_mol)
    return graph, np.concatenate(all_pos, axis=0), mol_id


# ---- named reduced-scale stand-ins for public datasets --------------------
_PRESETS = {
    # name: (nodes, avg_degree, exponent, feat_dim)
    "cora_like": (2708, 3.9, 1.3, 1433),
    "reddit_like": (8192, 48.0, 1.8, 300),
    "products_like": (16384, 25.0, 1.6, 100),
    "papers_like": (32768, 14.0, 1.7, 128),
}


def preset_graph(name: str, *, seed: int = 0,
                 scale: float = 1.0) -> tuple[CSRGraph, np.ndarray]:
    nodes, deg, exp, feat = _PRESETS[name]
    n = max(64, int(nodes * scale))
    g = power_law_graph(n, deg, exponent=exp, seed=seed)
    rng = np.random.default_rng(seed + 1)
    feats = rng.normal(size=(n, feat)).astype(np.float32)
    return g, feats
